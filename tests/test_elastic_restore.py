"""Elastic rescale: a checkpoint taken on one mesh restores onto a
different mesh (the EXPERIMENTS §Fault-tolerance claim), in a subprocess
with 8 forced host devices."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.parallel import sharding as sh
    from repro.training import checkpoint as ckpt
    from repro.launch import mesh as mesh_lib

    cfg = get_config("tinyllama-1.1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # "Train" on mesh A: shard params (data=4, model=2), save.
    mesh_a = mesh_lib.make_mesh((4, 2), ("data", "model"))
    sh.set_mesh_axis_sizes(mesh_a)
    spec = sh.sanitize_specs(sh.param_specs(cfg, params), params)
    p_a = jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh_a, s)),
        params, spec, is_leaf=lambda x: isinstance(x, P))
    d = tempfile.mkdtemp()
    ckpt.save(d, 1, {"params": p_a})

    # Restart on mesh B (2, 4) — the elastic rescale path.
    mesh_b = mesh_lib.make_mesh((2, 4), ("data", "model"))
    sh.set_mesh_axis_sizes(mesh_b)
    spec_b = sh.sanitize_specs(sh.param_specs(cfg, params), params)
    like = jax.tree.map(
        lambda x, s: jax.device_put(jnp.zeros_like(x),
                                    NamedSharding(mesh_b, s)),
        params, spec_b, is_leaf=lambda x: isinstance(x, P))
    restored = ckpt.restore(d, 1, {"params": like})["params"]

    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)
        # restored leaves actually live on mesh B
        assert b.sharding.mesh.shape["model"] == 4
    print("ELASTIC_OK")
""")


def test_restore_onto_different_mesh():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd=__file__.rsplit("/tests", 1)[0], timeout=600)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
