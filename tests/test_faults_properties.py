"""Randomized chaos schedules over a 2-replica router with fault injection.

The fleet analogue of tests/test_frontend_properties.py: no pump ever
starts — each trace drives both frontends' serialized engine interaction
directly (``fe._dispatch(fe._tick())``) and runs router failover by hand
(``fail_over_dead``), so every submit / cancel / crash / hang / drain
interleaving is a deterministic schedule.  Replica 0 runs under a drawn
``FaultPlan`` (maybe-crash, maybe-hang, maybe-raise, maybe-slow);
replica 1 stays clean so failover always has somewhere to go unless the
trace also drains it.

The draw core runs two ways: seeded ``random.Random`` traces ALWAYS run
(tier-1), and the same core sweeps under hypothesis where installed.

Properties, after every tick and at drain:

  * ``BlockStore`` invariants + ``shared_prefix_sound`` on every
    non-halted replica, every tick — faults must never corrupt a pool;
  * every stream TERMINATES (done or cancelled once the router is
    closed) — no consumer can be left hanging on a dead replica;
  * no token loss, no duplicates: everything a stream delivered is a
    PREFIX of the solo-engine greedy output for its prompt, and a
    completed stream equals it exactly — even when the request was
    failed over mid-decode (the bit-identity headline, randomized);
  * refcounts are zero fleet-wide after ``router.aclose()`` (the
    teardown leak fix this PR pins);
  * the stats ledger balances: failovers never exceed resubmission
    attempts, and at most one replica (the faulty one) dies.
"""
import asyncio
import random

import numpy as np
import pytest

import jax

from repro.configs.base import get_config
from repro.models import model as M
from repro.serving.engine import ServingEngine
from repro.serving.faults import FaultPlan, FaultyEngine
from repro.serving.frontend import CircuitBreaker, RejectedError
from repro.serving.router import ReplicaHealth, ReplicaRouter
from paged_invariants import shared_prefix_sound

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

_ENGINES = None


def _engines():
    """Module-lifetime engines (two replicas + a solo reference): jit
    traces compile once; FaultyEngine wrappers are rebuilt per trace
    (they are cheap and hold all the mutable fault state)."""
    global _ENGINES
    if _ENGINES is None:
        cfg = get_config("tinyllama-1.1b").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        _ENGINES = [ServingEngine(cfg, params, max_batch=3, max_len=32,
                                  eos_id=-1, block_size=4, num_blocks=10,
                                  prefill_chunk=8) for _ in range(3)]
    return _ENGINES


def _never_trips():
    return CircuitBreaker(window=4096, trip_pressure=4096,
                          sat_threshold=2.0)


def _lane_contents(eng):
    contents = {}
    for i, r in enumerate(eng._slot_req):
        if r is not None:
            contents[i] = eng._content_ids(r)
    for s in eng._prefilling:
        contents[s.lane] = eng._content_ids(s.req)
    return contents


class _SeededDraw:
    def __init__(self, seed):
        self._r = random.Random(seed)

    def ints(self, lo, hi, label=""):
        return self._r.randint(lo, hi)

    def maybe_int(self, lo, hi, label=""):
        if self._r.random() < 0.5:
            return None
        return self._r.randint(lo, hi)


class _HypothesisDraw:
    def __init__(self, data):
        self._data = data

    def ints(self, lo, hi, label=""):
        return self._data.draw(st.integers(lo, hi), label=label)

    def maybe_int(self, lo, hi, label=""):
        return self._data.draw(st.one_of(st.none(), st.integers(lo, hi)),
                               label=label)


def _draw_plan(d):
    """A fault schedule for replica 0, drawn event by event (so
    hypothesis shrinks toward the empty plan)."""
    plan = FaultPlan()
    crash = d.maybe_int(0, 8, label="crash_tick")
    if crash is not None:
        plan = plan + FaultPlan.crash_at(crash)
    hang = d.maybe_int(0, 6, label="hang_tick")
    if hang is not None:
        plan = plan + FaultPlan.hang_at(
            hang, duration=d.ints(2, 40, label="hang_len"))
    rse = d.maybe_int(0, 8, label="raise_tick")
    if rse is not None:
        plan = plan + FaultPlan.raise_at(rse)
    slow = d.maybe_int(0, 6, label="slow_tick")
    if slow is not None:
        plan = plan + FaultPlan.slow_from(
            slow, d.ints(2, 3, label="slow_factor"),
            d.ints(1, 6, label="slow_len"))
    return plan


async def _drain_stream(s):
    """Consume a stream to its terminator; never hangs if the contract
    holds (the ticket is done/cancelled, so the queue ends)."""
    toks, err = [], None
    try:
        async for t in s:
            toks.append(t)
    except Exception as e:
        err = e
    return toks, err


def _run_chaos(d):
    eng0, eng1, ref = _engines()
    plan = _draw_plan(d)
    fx = FaultyEngine(eng0, plan)
    r = ReplicaRouter(
        [fx, eng1], policy="round_robin",
        breaker_factory=_never_trips,
        health_factory=lambda: ReplicaHealth(deadline_ticks=16,
                                             crash_threshold=2),
        retry_budget=d.ints(0, 3, label="retry_budget"))
    for fe in r.frontends:
        fe.engine.on_token = fe._on_token  # what start() would wire
    n = d.ints(1, 4, label="n_requests")
    specs = []
    for k in range(n):
        plen = d.ints(4, 8, label=f"plen{k}")
        prompt = np.array([d.ints(1, 4, label=f"tok{k}")
                           for _ in range(plen)], np.int32)
        specs.append({
            "prompt": prompt,
            "budget": d.ints(1, 5, label=f"budget{k}"),
            "submit_tick": d.ints(0, 4, label=f"submit{k}"),
            "cancel_delay": d.maybe_int(0, 8, label=f"cancel{k}"),
        })
    # Maybe drain the CLEAN replica for a short window (failover during
    # it has nowhere to go and must surface an error, not hang).
    drain_at = d.maybe_int(0, 6, label="drain_at")
    drain_len = d.ints(1, 3, label="drain_len")
    streams = {}
    try:
        for tick in range(120):
            if drain_at is not None:
                if tick == drain_at:
                    r.drain(1)
                if tick == drain_at + drain_len:
                    r.undrain(1)
            for k, sp in enumerate(specs):
                if sp["submit_tick"] == tick:
                    try:
                        streams[k] = asyncio.run(r.submit(
                            sp["prompt"], max_new_tokens=sp["budget"]))
                    except RejectedError as e:
                        assert e.kind in ("backpressure", "breaker")
                if (k in streams and sp["cancel_delay"] is not None
                        and tick == sp["submit_tick"] + sp["cancel_delay"]):
                    asyncio.run(streams[k].aclose())
            for fe in r.frontends:
                if not fe._stopped:
                    fe._dispatch(fe._tick())
            if r._dead_pending:
                asyncio.run(r.fail_over_dead())
            for fe in r.frontends:
                if fe._stopped:
                    continue
                inner = getattr(fe.engine, "engine", fe.engine)
                inner._alloc.check_invariants()
                shared_prefix_sound(inner._alloc, _lane_contents(inner))
            done_submitting = tick >= max(sp["submit_tick"]
                                          for sp in specs)
            if done_submitting and not any(
                    fe._inflight or (not fe._stopped
                                     and fe._has_engine_work())
                    for fe in r.frontends):
                break
        else:
            raise AssertionError("chaos trace did not settle in 120 ticks")
    finally:
        asyncio.run(r.aclose())  # asserts zero live blocks fleet-wide

    # -- drain-time properties ----------------------------------------------
    for eng in (eng0, eng1):
        eng._alloc.check_invariants()
        assert eng._alloc.live_blocks == 0
        assert not eng.poisoned  # boundary injection never corrupts
    ref_uids = {k: ref.submit(sp["prompt"],
                              max_new_tokens=sp["budget"])
                for k, sp in enumerate(specs)}
    ref_out = ref.run()
    for k, s in streams.items():
        live = s._live()[1]
        assert live.done or live.cancelled, \
            "stream left hanging after router close"
        toks, err = asyncio.run(_drain_stream(s))
        expect = ref_out[ref_uids[k]]
        assert toks == expect[:len(toks)], (
            f"stream {k} diverged from the solo greedy run "
            f"(got {toks}, reference {expect})")
        if live.done and live.result is not None and err is None \
                and not live.cancelled:
            assert toks == expect, (
                f"completed stream {k} is not bit-identical after "
                f"{live.retries} failover(s)")
    assert r.stats.failovers <= r.stats.retries
    assert r.stats.replica_deaths <= 1
    assert (r.stats.replica_deaths == 1) == (r.health[0].state == "dead")
    assert r.health[1].state in ("healthy", "suspect")


@pytest.mark.parametrize("seed", range(6))
def test_seeded_chaos_schedules(seed):
    """Tier-1: fixed-seed chaos traces of the same core."""
    _run_chaos(_SeededDraw(seed))


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.data())
    def test_hypothesis_chaos_schedules(data):
        _run_chaos(_HypothesisDraw(data))
else:
    @pytest.mark.skip(reason="hypothesis not installed; the seeded "
                             "traces above cover the same core")
    def test_hypothesis_chaos_schedules():
        pass
