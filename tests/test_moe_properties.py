"""MoE dispatch properties (moved from test_serving.py; needs hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_config
from repro.models import moe as moe_lib


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000))
def test_moe_capacity_drops_are_bounded(seed):
    """With capacity_factor >= 1 and balanced-ish routing, most tokens get
    served; dropped tokens produce zero expert output (not NaN)."""
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, cfg.d_model)
                          ).astype(jnp.bfloat16)
    out, aux = moe_lib.apply_moe(cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    assert float(aux) >= 0.99  # >= 1 for any distribution (Switch aux loss)


def test_moe_identical_tokens_identical_outputs():
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    p = moe_lib.init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.broadcast_to(
        jax.random.normal(jax.random.PRNGKey(1), (1, 1, cfg.d_model)),
        (1, 8, cfg.d_model)).astype(jnp.bfloat16)
    out, _ = moe_lib.apply_moe(cfg, p, x)
    out = np.asarray(out, np.float32)
    # All-but-dropped identical tokens produce identical outputs; with
    # capacity >= 8 nothing is dropped here.
    for i in range(1, 8):
        served = np.abs(out[0, i]).sum() > 0
        if served:
            np.testing.assert_allclose(out[0, i], out[0, 0], atol=1e-5)
